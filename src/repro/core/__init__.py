"""Core: the paper's contribution — serverless search/serving substrate.

State lives in :mod:`repro.core.object_store`; the Lucene ``Directory`` seam
is :mod:`repro.core.directory`; warm/cold caching in :mod:`repro.core.cache`;
the FaaS fleet in :mod:`repro.core.runtime`; REST fronting in
:mod:`repro.core.gateway`; the Lambda cost model in :mod:`repro.core.cost`;
document partitioning + top-k merge in :mod:`repro.core.partition`; batch
index refresh in :mod:`repro.core.refresh`.
"""

from repro.core.cache import HydrationCache, pytree_nbytes
from repro.core.cost import CostLedger, Invocation, paper_headline_cost
from repro.core.directory import Directory, IndexInput, RamDirectory, StoreDirectory
from repro.core.gateway import Gateway, Response
from repro.core.kvstore import KVStore
from repro.core.object_store import (
    FilesystemBackend,
    MemoryBackend,
    NetworkModel,
    NoSuchKey,
    ObjectStore,
)
from repro.core.partition import ScatterGather, merge_topk, shard_topk_merge
from repro.core.refresh import AssetCatalog, refresh_fleet
from repro.core.runtime import FaaSRuntime, RuntimeConfig

__all__ = [
    "AssetCatalog", "CostLedger", "Directory", "FaaSRuntime",
    "FilesystemBackend", "Gateway", "HydrationCache", "IndexInput",
    "Invocation", "KVStore", "MemoryBackend", "NetworkModel", "NoSuchKey",
    "ObjectStore", "RamDirectory", "Response", "RuntimeConfig",
    "ScatterGather", "StoreDirectory", "merge_topk", "paper_headline_cost",
    "pytree_nbytes", "refresh_fleet", "shard_topk_merge",
]
