"""Cost-ledger-driven fleet autoscaling — replicas as a runtime control loop.

The paper's economics ("pay only for queries actually served") and its tail
story (replicated partitions + hedged scatter legs) pull in opposite
directions when the replica count is a BUILD-TIME constant: an
over-provisioned fleet pays a keep-warm/hedge tax through every quiet hour,
a cold-heavy one re-buys the p99 blowups hedging exists to fix. The
:class:`FleetController` turns that $/1k-queries vs. p99 operating point
into feedback: on a virtual-clock tick it reads, per replica group,

* recent WARM latency quantiles (``FaaSRuntime.latency_percentiles`` over
  the group — the same baseline the :class:`~repro.core.partition.HedgePolicy`
  hedges against),
* queue-wait/cold-boot projections (``FaaSRuntime.probe``, no fleet
  mutation), and
* the :class:`~repro.core.cost.CostLedger`'s hedge/idle attribution — what
  tail mitigation and standby capacity actually cost since the last tick,

then steers the group toward a PER-GROUP replica target — real traffic is
Zipf-skewed, and the serverless bet (pay only for what runs) only pays off
when a hot head partition can hold R=3 while its cold siblings drain to
R=1 under the same fleet-wide traffic. Capacity moves **up** by
registering a fresh ``search-p{p}rN`` function over the partition's
already-published segment (one ``AssetCatalog`` entry, N pools — the PR 2
invariant; nothing is re-published) and prewarming its pool; **down** by
draining the newest replica through ``FaaSRuntime.retire`` so in-flight
work finishes and the keep-alive pings that made it cost money stop.

Keep-alive is the controller's second job: a pool the provider would reap
before its next use gets a ping, billed to the ledger's IDLE line — which
is exactly the spend a scale-down decision needs to see. Ticks piggyback
on request arrivals — the gateway coordinator calls :meth:`maybe_tick`
AFTER dispatch, never before: a pre-dispatch ping races the request it
rides in on for the pool's single idle instance and causes the very cold
start it exists to prevent — and additionally fire when the kill log grows
(the analogue of a spot/instance-termination notice, so routing and
capacity react to a killed pool before the next full period). Long quiet
stretches need an out-of-band timer driving :meth:`maybe_tick` as well
(B10 does this), or pools expire between sparse arrivals.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

from repro.core.partition import ScatterGather
from repro.core.runtime import FaaSRuntime, Handler


@dataclasses.dataclass
class AutoscalePolicy:
    """Knobs for one controller. Defaults are deliberately conservative:
    scale up eagerly on tail pressure (a cold start costs ~10× a warm
    query), scale down only after ``idle_ticks_to_retire`` consecutive
    quiet ticks (hysteresis — a diurnal lull should retire standby pools,
    a two-query gap should not).

    Replica bounds may be ONE int pair (every partition shares them) or a
    per-partition sequence — a fleet whose partitions are known a priori to
    be heterogeneous (a Zipf-hot head partition, a cold tail) can bound
    each group separately, and the controller's per-group targets do the
    rest at runtime."""

    min_replicas: "int | Sequence[int]" = 1
    max_replicas: "int | Sequence[int]" = 3
    tick_s: float = 1.0                 # control period (virtual seconds)
    rate_window_s: float = 2.0          # trailing window for arrival rate
    # demand thresholds are INVOCATIONS/s per replica (a micro-batch
    # occupies an instance once, so it counts once): scale up above
    # up_qps_per_replica, count an idle tick below down_qps_per_replica
    up_qps_per_replica: float = 10.0
    down_qps_per_replica: float = 1.0
    idle_ticks_to_retire: int = 2       # ...for this many consecutive ticks
    # up-scale hysteresis: how many CONSECUTIVE pressured ticks before a
    # scale-up lands. 1 (default) reacts within one control period — right
    # when pressure means kills or burst onset. Raise it for fleets whose
    # pressure has known sub-tick transients (a generation rollover's
    # hydration stall congests every pool for ~2 ticks; scaling up buys
    # pools that would themselves hydrate) so only PERSISTENT pressure
    # grows the fleet.
    up_ticks_to_scale: int = 1
    up_overhead_s: float | None = None  # queue/cold projection trigger;
    #                                     None → max(provision/2, 2× warm p50)
    # The MEASURED cold overhead (provision + first-query hydration) the
    # projection floor derives from. The runtime's ``provision_s`` alone
    # under-states an eager-hydration fleet's cold cost (~0.47 s vs the
    # 0.15 s boot) and over-states a lazy-hydration one's (~0.2 s) — B13
    # measures both profiles; feed its number here so the scale-up trigger
    # prices cold starts the fleet will ACTUALLY pay. None keeps the PR 3
    # provision_s/2 floor (bit-identical pre-existing behaviour).
    cold_overhead_s: float | None = None
    # Little's-law capacity target per group: replicas chase
    # ceil(arrival_rate × warm_p50 / target_utilization), the rule that
    # makes a fleet HETEROGENEOUS under skew — a partition whose vmapped
    # eval runs 7× longer (7× the documents) needs 7× the pool-seconds at
    # the same invocation rate, which no shared invocations/s threshold
    # can express. None disables (PR 3's escalation triggers only).
    target_utilization: float | None = 0.6
    # Execution-model scale applied to the warm p50 the concurrency rule
    # reads — one float for the fleet, or a per-partition sequence. A
    # PRUNED fleet's observable service time carries the dense-path
    # constant (the modeled clock charges ``sim_exec_s`` calibrated
    # against the dense pass; a measured clock still includes the dense
    # top-k scan), but the work its kernel actually sustains at saturation
    # is linear in blocks TOUCHED — B9b measures that fraction directly
    # (the gated ``b9b_pruned_blocks_touched_frac_*`` rows, ~0.02 under
    # tight single-term bounds). Feed the measured fraction here and
    # Little's law prices warm service time as frac × p50, so a pruned
    # fleet stops buying ~50× the pools its own arithmetic needs — and the
    # over-provisioned drain rule shrinks one that already did. 1.0
    # (default) keeps every pre-existing decision bit-identical.
    exec_scale: "float | Sequence[float]" = 1.0
    # newest-N warm records behind every quantile the controller reads —
    # the SAME window HedgePolicy scans, so scaling and hedging judge one
    # latency regime (unwindowed, a long-running fleet would hedge on
    # recent behaviour while scaling on stale history)
    warm_window: int = 256
    keepalive: bool = True              # ping pools the provider would reap
    keepalive_margin_s: float | None = None  # ping when expiry < margin;
    #                                     None → idle_timeout / 2
    prewarm: bool = True                # ping a just-registered replica


@dataclasses.dataclass
class _GroupState:
    base: str                 # the partition's base function name (group[0])
    next_replica: int         # suffix for the next registered replica
    idle_ticks: int = 0
    over_ticks: int = 0       # consecutive ticks above the concurrency target
    up_ticks: int = 0         # consecutive ticks WITH up-pressure (hysteresis)
    last_target: int = 0      # the target the last tick computed (introspection)


class FleetController:
    """The feedback loop between one runtime's ledger and one scatter's
    replica groups.

    ``handler_factories[p]()`` must build a fresh handler serving partition
    ``p``'s published segment — the controller never touches the object
    store, so a scale-up is registration + prewarm, never a re-publish.
    ``ping_payload`` is the no-op request keep-alive and prewarm pings
    carry (e.g. ``{"q": "", "k": 1, "fetch_docs": False}``).
    """

    def __init__(self, runtime: FaaSRuntime, scatter: ScatterGather,
                 handler_factories: Sequence[Callable[[], Handler]],
                 policy: AutoscalePolicy | None = None, *,
                 ping_payload: Any = None) -> None:
        if len(handler_factories) != len(scatter.groups):
            raise ValueError(
                f"{len(handler_factories)} handler factories for "
                f"{len(scatter.groups)} replica groups")
        self.runtime = runtime
        self.scatter = scatter
        self.factories = list(handler_factories)
        self.policy = policy if policy is not None else AutoscalePolicy()
        self.ping_payload = ping_payload if ping_payload is not None else {}
        for bound in (self.policy.min_replicas, self.policy.max_replicas):
            if (not isinstance(bound, int)
                    and len(bound) != len(scatter.groups)):
                raise ValueError(
                    f"per-partition replica bounds need one entry per group: "
                    f"{len(bound)} bounds for {len(scatter.groups)} groups")
        scale = self.policy.exec_scale
        if (not isinstance(scale, (int, float))
                and len(scale) != len(scatter.groups)):
            raise ValueError(
                f"per-partition exec_scale needs one entry per group: "
                f"{len(scale)} entries for {len(scatter.groups)} groups")
        self.groups = [_GroupState(base=g[0], next_replica=len(g),
                                   last_target=len(g))
                       for g in scatter.groups]
        self.events: list[dict] = []     # scale_up / retire, with reasons
        self.pings = 0
        # admission sheds the gateway reported (Gateway.route_batched's
        # on_shed hook): refused demand never reaches a pool, so none of
        # the record-derived signals can see it — without this counter a
        # fleet in deep overload looks QUIET to the controller (sheds
        # suppress arrivals) and would never buy the capacity that ends
        # the shedding
        self.sheds_seen = 0              # cumulative (introspection)
        self._sheds = 0                  # since the last tick (the signal)
        self._last_tick = -math.inf
        self._rec_ptr = 0                # window start into runtime.records
        self._kill_ptr = 0               # interrupt: unseen kill_log entries
        self._last_spend = dict(self.runtime.ledger.attribution())

    # -- the loop entry points -------------------------------------------------

    def maybe_tick(self, now: float | None = None) -> bool:
        """Tick if a full period elapsed OR the kill log grew (termination
        notices shouldn't wait out the period). Called by the gateway
        coordinator at every request arrival AFTER dispatch (pre-dispatch
        keep-alive pings would race the request for the pool's idle
        instance), and by any out-of-band timer the deployment runs."""
        t = self.runtime.clock if now is None else now
        if (t - self._last_tick >= self.policy.tick_s
                or len(self.runtime.kill_log) > self._kill_ptr):
            self.tick(t)
            return True
        return False

    def note_shed(self, t: float) -> None:
        """One admission-shed arrival (gateway backpressure). Counted as
        scale-up pressure at the next tick — the only demand signal a shed
        leaves, since the request is refused before any invocation."""
        self.sheds_seen += 1
        self._sheds += 1

    def tick(self, now: float | None = None) -> None:
        t = self.runtime.clock if now is None else now
        pol = self.policy
        window = [r for r in self.runtime.records[self._rec_ptr:]
                  if not r.keepalive]
        self._rec_ptr = len(self.runtime.records)
        self._kill_ptr = len(self.runtime.kill_log)
        self._last_tick = t
        # what the fleet spent since the last look: hedge tax (tail
        # mitigation that fired) and idle tax (standby pools kept warm)
        spend = self.runtime.ledger.attribution()
        spend_delta = {k: spend[k] - self._last_spend.get(k, 0.0)
                       for k in spend}
        self._last_spend = spend

        sheds, self._sheds = self._sheds, 0
        for p, group in enumerate(self.scatter.groups):
            self._control_group(p, group, window, spend_delta, t,
                                sheds=sheds)
        if pol.keepalive:
            self._keepalive(t)

    # -- per-group control ----------------------------------------------------

    def _group_rate(self, group: list[str], now: float) -> float:
        """Arrival rate (INVOCATIONS/s) over the trailing rate window. An
        invocation is the capacity-consuming unit — a micro-batch occupies
        an instance once however many queries it carries — so the policy's
        qps thresholds are per-invocation, and batched traffic reads as its
        invocation rate, not its (higher) logical-query rate."""
        names = set(group)
        cutoff = now - self.policy.rate_window_s
        n = 0
        for r in reversed(self.runtime.records):
            if r.t_arrival < cutoff:
                break
            if r.fn in names and not r.keepalive:
                n += 1
        return n / self.policy.rate_window_s

    def _bounds(self, p: int) -> tuple[int, int]:
        """(min, max) replicas for partition ``p`` — shared ints or the
        per-partition entries of a heterogeneous bounds sequence."""
        pol = self.policy
        lo = (pol.min_replicas if isinstance(pol.min_replicas, int)
              else pol.min_replicas[p])
        hi = (pol.max_replicas if isinstance(pol.max_replicas, int)
              else pol.max_replicas[p])
        return lo, max(lo, hi)

    def _exec_scale(self, p: int) -> float:
        """Partition ``p``'s execution-model scale — the measured
        work-per-observed-second ratio (e.g. B9b's blocks-touched fraction
        on a pruned fleet) the concurrency rule multiplies into warm p50."""
        scale = self.policy.exec_scale
        return float(scale if isinstance(scale, (int, float)) else scale[p])

    def _overhead_threshold(self, group: list[str]) -> float:
        if self.policy.up_overhead_s is not None:
            return self.policy.up_overhead_s
        wp50 = self.runtime.latency_percentiles(
            group, qs=(0.5,), warm_only=True,
            window=self.policy.warm_window)[0.5]
        cold = (self.policy.cold_overhead_s
                if self.policy.cold_overhead_s is not None
                else self.runtime.config.provision_s)
        floor = cold / 2
        return floor if math.isnan(wp50) else max(floor, 2.0 * wp50)

    def _control_group(self, p: int, group: list[str], window: list,
                       spend_delta: dict, now: float, *,
                       sheds: int = 0) -> None:
        """Steer partition ``p``'s group toward ITS OWN replica target.

        Every signal here is per-group — this group's trailing arrival
        share, this group's warm quantiles (windowed to the current
        latency regime), this group's hedge/cold pressure — so a
        Zipf-hot partition holds R=3 while its cold siblings drain to
        R=1 under the same fleet-wide traffic. The escalation triggers
        (demand/hedge/tail/projection) step capacity by one, PR 3 style;
        the Little's-law concurrency rule may target several steps at
        once, and the controller walks the whole gap in one tick (a
        saturated head partition should not wait N control periods for
        capacity the math already justifies)."""
        pol, st = self.policy, self.groups[p]
        lo, hi = self._bounds(p)
        names = set(group)
        grp = [r for r in window if r.fn in names]
        # capacity pressure counts FRESH container boots only: a
        # hydration-only cold (warm pool, new index generation after a
        # commit) is content turnover every pool pays once per generation —
        # more pools would mean MORE hydrations, not fewer
        colds = sum(r.provisioned for r in grp)
        hedges = sum(r.hedged for r in grp)
        rate = self._group_rate(group, now)
        # project one tick AHEAD: at the tick instant itself the request
        # just dispatched still occupies its instance, and a pool serving
        # exactly one in-flight query would look like a cold start to a
        # same-instant probe. Queue pressure that persists a tick out is
        # the real signal.
        horizon = now + self.policy.tick_s
        best_overhead = min(
            (sum(self.runtime.probe(f, horizon)) for f in group), default=0.0)

        # tail pressure only justifies capacity when there is actually
        # traffic: a once-an-hour query on a fleet whose pools expire
        # between arrivals is cold BECAUSE it's idle — adding a second
        # cold pool would burn a rehydration per burst-that-never-comes
        # and the cold-in-window signal would block every retire
        active = rate >= pol.down_qps_per_replica
        target, up_reason = len(group), None
        if rate / len(group) > pol.up_qps_per_replica:
            target = len(group) + 1
            up_reason = f"demand: {rate:.1f} q/s over {len(group)} pool(s)"
        elif sheds:
            # NOT gated on `active`: shed arrivals never become records,
            # so deep overload reads as a LOW arrival rate here — the shed
            # count is the only trace the refused demand leaves
            target = len(group) + 1
            up_reason = f"backpressure: {sheds} shed arrival(s) since last tick"
        elif active and hedges:
            target = len(group) + 1
            up_reason = (f"hedge tax: {hedges} backup leg(s), "
                         f"${spend_delta.get('hedge', 0.0):.6f} since last tick")
        elif active and colds:
            target = len(group) + 1
            up_reason = f"tail: {colds} cold boot(s) in window"
        elif active and best_overhead > self._overhead_threshold(group):
            target = len(group) + 1
            up_reason = f"projection: {best_overhead * 1e3:.0f} ms queued/cold"

        # the heterogeneous-fleet rule: offered concurrency (Little's law,
        # arrival rate × warm service time) over the utilization target is
        # how many pools THIS group's load needs — a head partition whose
        # eval runs 7× longer demands 7× the capacity at the same
        # invocation rate, invisible to any shared invocations/s threshold
        need = None
        if active and pol.target_utilization:
            wp50 = self.runtime.latency_percentiles(
                group, qs=(0.5,), warm_only=True,
                window=pol.warm_window)[0.5]
            if not math.isnan(wp50):
                # the exec model: observed p50 × this partition's measured
                # work fraction (B9b's blocks-touched frac on pruned
                # fleets; 1.0 = the observed time IS the work)
                svc = wp50 * self._exec_scale(p)
                need = math.ceil(rate * svc / pol.target_utilization)
                if need > target:
                    target = need
                    up_reason = (
                        f"concurrency: {rate:.1f} inv/s × {svc * 1e3:.0f} ms "
                        f"modeled warm p50 ÷ {pol.target_utilization:g} util "
                        f"→ {need} pool(s)")

        target = min(target, hi)
        st.last_target = max(target, min(len(group), hi))
        if target > len(group):
            st.idle_ticks = st.over_ticks = 0
            st.up_ticks += 1
            if st.up_ticks < pol.up_ticks_to_scale:
                return                  # pressure must persist before it buys pools
            while len(self.scatter.groups[p]) < target:
                self._scale_up(p, st, now, up_reason)
            st.up_ticks = 0
            return
        st.up_ticks = 0
        if up_reason is not None:
            st.idle_ticks = st.over_ticks = 0   # pressure at the cap ≠ idleness
            return

        if (len(group) > lo
                and rate / len(group) < pol.down_qps_per_replica):
            st.over_ticks = 0
            st.idle_ticks += 1
            if st.idle_ticks >= pol.idle_ticks_to_retire:
                self._retire(p, group, st, now,
                             f"idle: {rate:.2f} q/s, no hedges, idle tax "
                             f"${spend_delta.get('idle', 0.0):.6f} since last tick")
                st.idle_ticks = 0
        elif need is not None and need < len(group) > lo:
            # OVER-provisioned under live traffic: a transient (one commit's
            # concurrency spike, a one-off cold) grew the group past what
            # its own concurrency math justifies, and the idle rule will
            # never fire while traffic flows. Converge DOWN to the target
            # with the same hysteresis scale-down uses — so a tail
            # partition that briefly ballooned drains back to R=1 while a
            # head partition whose demand is real keeps its pools (its
            # up-pressure resets the counter every tick).
            st.idle_ticks = 0
            st.over_ticks += 1
            if st.over_ticks >= pol.idle_ticks_to_retire:
                self._retire(p, group, st, now,
                             f"over-provisioned: {rate:.1f} inv/s needs "
                             f"{need} pool(s), running {len(group)}")
                st.over_ticks = 0
        else:
            st.idle_ticks = st.over_ticks = 0

    def _scale_up(self, p: int, st: _GroupState, now: float,
                  reason: str) -> None:
        fn = f"{st.base}r{st.next_replica}"
        st.next_replica += 1
        self.runtime.register(fn, self.factories[p]())
        self.scatter.add_replica(p, fn)
        if self.policy.prewarm:
            self.runtime.invoke(fn, self.ping_payload, t_arrival=now,
                                keepalive=True)
            self.pings += 1
        self.events.append({"t": now, "partition": p, "action": "scale_up",
                            "fn": fn, "reason": reason,
                            "replicas": len(self.scatter.groups[p])})

    def _retire(self, p: int, group: list[str], st: _GroupState,
                now: float, reason: str) -> None:
        fn = group[-1]                  # newest replica; base never leaves
        self.scatter.remove_replica(p, fn)
        self.runtime.retire(fn, t=now)
        self.events.append({"t": now, "partition": p, "action": "retire",
                            "fn": fn, "reason": reason,
                            "replicas": len(self.scatter.groups[p])})

    # -- keep-warm ------------------------------------------------------------

    def _keepalive(self, now: float) -> None:
        """Ping every pool the provider would reap before we'd plausibly
        touch it again. Pools fed by live traffic never need it; standby
        replicas are pinged roughly every margin-worth of quiet — the idle
        spend this books is precisely the standing cost a retire decision
        weighs against the hedge tax the replica saves."""
        margin = self.policy.keepalive_margin_s
        if margin is None:
            margin = self.runtime.config.idle_timeout_s / 2
        for group in self.scatter.groups:
            for fn in group:
                # a pool with in-flight work is being kept warm by its own
                # traffic — pinging it would race the live request for the
                # idle instance and force a cold start (see pool_busy)
                if self.runtime.pool_busy(fn, now):
                    continue
                expiry = self.runtime.pool_expiry_s(fn, now)
                if expiry is None or expiry < margin:
                    self.runtime.invoke(fn, self.ping_payload,
                                        t_arrival=now, keepalive=True)
                    self.pings += 1

    # -- introspection --------------------------------------------------------

    def replica_counts(self) -> list[int]:
        return [len(g) for g in self.scatter.groups]

    def replica_targets(self) -> list[int]:
        """Per-group targets from the last tick — the heterogeneous shape
        the controller is steering toward (counts converge to targets as
        scale-ups land and idle hysteresis drains)."""
        return [st.last_target for st in self.groups]

    def stats(self) -> dict:
        led = self.runtime.ledger
        return {
            "replica_counts": self.replica_counts(),
            "replica_targets": self.replica_targets(),
            "scale_ups": sum(e["action"] == "scale_up" for e in self.events),
            "retires": sum(e["action"] == "retire" for e in self.events),
            "pings": self.pings,
            "sheds_seen": self.sheds_seen,
            "spend": led.attribution(),
        }
