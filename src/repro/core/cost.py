"""Lambda cost model — paper §2's economics, reproduced exactly.

"Lambda invocation is charged in terms of memory and time; at the time of
writing, each GB/s costs $0.000016667. ... let's assume a (generous) instance
with 2GB memory running for 300ms; this translates into 100,000 queries per
US dollar. The beauty of the serverless cost model is that query load is
entirely fungible — 10 QPS for 10,000 seconds or 100 QPS for 1,000 seconds
costs exactly the same."
"""

from __future__ import annotations

import dataclasses


GB = 1024 ** 3

# AWS Lambda pricing at the time of the paper's writing.
PRICE_PER_GB_S = 0.000016667
PRICE_PER_REQUEST = 0.0000002   # $0.20 / 1M requests (ignored by the paper's
                                # round numbers; tracked separately here)
LAMBDA_BILLING_QUANTUM_S = 0.001  # post-2020 1 ms billing granularity


@dataclasses.dataclass(frozen=True)
class Invocation:
    memory_bytes: int
    duration_s: float
    cold_start: bool = False
    hedge: bool = False       # a backup leg fired for tail mitigation
    idle: bool = False        # keep-alive ping: standby capacity, not a query
    write: bool = False       # indexing work: delta pack / merge, not a query
    backfill: bool = False    # partial → full hydration upgrade, not a query


@dataclasses.dataclass
class CostLedger:
    """Accumulates per-invocation GB·s charges.

    Hedged backup legs are charged like any other invocation — FaaS offers
    no cancellation, so a losing leg runs (and bills) to completion — but
    they are additionally tracked in ``hedge_gb_seconds``/``hedge_invocations``
    so the tail-mitigation tax is visible next to the latency it buys.

    Keep-alive pings (``idle=True``) are the other standing tax: a standby
    replica pool answers no query but must be touched before the provider
    reaps it, and every touch bills. Attributing that spend separately
    (``idle_gb_seconds``/``idle_invocations``) is what lets a scale-down
    decision see what a pool costs just to exist — retire it and the idle
    line strictly stops growing.

    Writer invocations (``write=True``) are the NRT ingestion tax: delta
    packing and merge compaction run as Lambda work and bill like any
    invocation, but answer no query — a $/1k-queries number that silently
    folded indexing into serving would make update-heavy workloads look
    like expensive queries instead of cheap queries plus an indexing bill.

    Backfill charges (``backfill=True``) are the lazy-hydration deferral
    tax: a cold instance answers its first query from range reads of only
    the queried terms' blocks, then upgrades partial → full OFF the
    critical path. That upgrade still runs on the instance and bills
    GB·s, but it serves no query and adds no latency — folding it into
    serving would hide exactly the trade lazy hydration makes (cheap
    first response now, deferred bulk transfer later), so it gets its own
    line (``backfill_gb_seconds``/``backfill_invocations``).
    """

    gb_seconds: float = 0.0
    invocations: int = 0
    cold_starts: int = 0
    duration_s: float = 0.0
    hedge_gb_seconds: float = 0.0
    hedge_invocations: int = 0
    idle_gb_seconds: float = 0.0
    idle_invocations: int = 0
    write_gb_seconds: float = 0.0
    write_invocations: int = 0
    backfill_gb_seconds: float = 0.0
    backfill_invocations: int = 0
    # Admission-shed arrivals: rejected at the gateway with 429 before any
    # dispatch, so they bill NOTHING — the GB·s line exists only to pin that
    # claim (it must stay 0.0 forever; a nonzero value means a shed request
    # leaked into the fleet).
    shed_requests: int = 0
    shed_gb_seconds: float = 0.0

    def charge(self, inv: Invocation) -> float:
        quantum = LAMBDA_BILLING_QUANTUM_S
        billed_s = max(quantum,
                       -(-inv.duration_s // quantum) * quantum)  # ceil to quantum
        gbs = (inv.memory_bytes / GB) * billed_s
        self.gb_seconds += gbs
        self.invocations += 1
        self.cold_starts += int(inv.cold_start)
        self.duration_s += inv.duration_s
        if inv.hedge:
            self.hedge_gb_seconds += gbs
            self.hedge_invocations += 1
        if inv.idle:
            self.idle_gb_seconds += gbs
            self.idle_invocations += 1
        if inv.write:
            self.write_gb_seconds += gbs
            self.write_invocations += 1
        if inv.backfill:
            self.backfill_gb_seconds += gbs
            self.backfill_invocations += 1
        return gbs * PRICE_PER_GB_S

    def record_shed(self) -> None:
        """Count an admission-shed arrival. Sheds never dispatch, so no
        ``Invocation`` exists to charge — the count is the whole bill."""
        self.shed_requests += 1

    @property
    def compute_dollars(self) -> float:
        return self.gb_seconds * PRICE_PER_GB_S

    @property
    def request_dollars(self) -> float:
        return self.invocations * PRICE_PER_REQUEST

    @property
    def total_dollars(self) -> float:
        return self.compute_dollars + self.request_dollars

    @property
    def hedge_dollars(self) -> float:
        """The tail-mitigation tax: compute dollars spent on backup legs."""
        return self.hedge_gb_seconds * PRICE_PER_GB_S

    @property
    def idle_dollars(self) -> float:
        """The standby tax: compute dollars spent keeping pools warm."""
        return self.idle_gb_seconds * PRICE_PER_GB_S

    @property
    def write_dollars(self) -> float:
        """The ingestion tax: compute dollars spent packing deltas/merges."""
        return self.write_gb_seconds * PRICE_PER_GB_S

    @property
    def backfill_dollars(self) -> float:
        """The deferral tax: compute dollars spent upgrading partial → full."""
        return self.backfill_gb_seconds * PRICE_PER_GB_S

    def attribution(self) -> dict[str, float]:
        """Compute-dollar breakdown: serving / hedge / idle / write /
        backfill sum to ``compute_dollars`` (the classes are disjoint: a
        backup leg answers a query, a keep-alive answers none, a writer
        indexes, a backfill moves bytes for queries not yet asked)."""
        hedge, idle = self.hedge_dollars, self.idle_dollars
        write, backfill = self.write_dollars, self.backfill_dollars
        return {
            "serving": self.compute_dollars - hedge - idle - write - backfill,
            "hedge": hedge,
            "idle": idle,
            "write": write,
            "backfill": backfill,
        }

    def queries_per_dollar(self) -> float:
        if self.total_dollars == 0:
            return float("inf")
        return self.invocations / self.total_dollars

    def dollars_per_1k(self, n_queries: int) -> float:
        """$ per 1000 LOGICAL queries — the caller supplies the query count
        because hedging makes invocations ≠ queries (backup legs bill but
        answer no extra query).

        Zero-traffic guard: a just-built fleet that has served nothing and
        spent nothing reports $0 — the true unit cost of zero queries at
        zero spend, and what a dashboard should show before traffic, never
        a ZeroDivisionError. Spend WITHOUT queries (keep-alive pings,
        prewarming, writer invocations before the first search) is NaN:
        there is no per-query number that honestly describes a bill no
        query caused."""
        if n_queries <= 0:
            return 0.0 if self.total_dollars == 0.0 else float("nan")
        return self.total_dollars / n_queries * 1000.0


def paper_headline_cost(memory_gb: float = 2.0, duration_s: float = 0.3) -> float:
    """The paper's round-number calculation: queries per dollar for a 2GB
    instance running 300 ms (compute charge only, as the paper does)."""
    dollars_per_query = memory_gb * duration_s * PRICE_PER_GB_S
    return 1.0 / dollars_per_query


def fungibility_check(qps_a: float, secs_a: float, qps_b: float, secs_b: float,
                      memory_gb: float = 2.0, duration_s: float = 0.3) -> tuple[float, float]:
    """Cost of two load shapes with equal total queries — they must match
    (paper: 10 QPS × 10,000 s == 100 QPS × 1,000 s)."""
    cost = lambda qps, secs: qps * secs * memory_gb * duration_s * PRICE_PER_GB_S
    return cost(qps_a, secs_a), cost(qps_b, secs_b)


# -- TPU-side serving-cost adaptation ---------------------------------------
#
# The same fungible per-invocation accounting applied to TPU partitions: a
# "serverless TPU instance" is billed chip-seconds; the ledger form is
# identical, only the unit price changes. This lets benchmarks compare the
# paper's Lambda economics with a TPU-v5e serving deployment.

TPU_V5E_DOLLARS_PER_CHIP_HOUR = 1.2  # on-demand list price, order of magnitude


@dataclasses.dataclass
class TPUCostLedger:
    chip_seconds: float = 0.0
    invocations: int = 0

    def charge(self, n_chips: int, duration_s: float) -> float:
        cs = n_chips * duration_s
        self.chip_seconds += cs
        self.invocations += 1
        return cs / 3600.0 * TPU_V5E_DOLLARS_PER_CHIP_HOUR

    @property
    def total_dollars(self) -> float:
        return self.chip_seconds / 3600.0 * TPU_V5E_DOLLARS_PER_CHIP_HOUR

    def queries_per_dollar(self) -> float:
        if self.total_dollars == 0:
            return float("inf")
        return self.invocations / self.total_dollars
