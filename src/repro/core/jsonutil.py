"""Bytes-in/bytes-out JSON with orjson as the fast path.

Owns the orjson-vs-stdlib decision in ONE place: when orjson is installed
its ``dumps``/``loads`` are re-exported directly; otherwise a stdlib shim
with the same bytes contract takes over, so the whole stack (KVStore items,
asset manifests, index/checkpoint metadata) works on a bare environment.
Callers import unconditionally::

    from repro.core import jsonutil as orjson
"""

from __future__ import annotations

import json
import math
from typing import Any

import numpy as np

JSONDecodeError = json.JSONDecodeError


def _default(obj: Any):
    # orjson serializes numpy scalars/arrays natively with OPT_SERIALIZE_NUMPY;
    # metadata here only carries scalars, but accept arrays for parity.
    if isinstance(obj, np.generic):
        return obj.item()
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def _sanitize(obj: Any) -> Any:
    """NaN/Infinity (Python or numpy float) → null, matching orjson."""
    if isinstance(obj, (float, np.floating)) and not math.isfinite(obj):
        return None
    if isinstance(obj, np.ndarray):
        return _sanitize(obj.tolist())
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    return obj


def dumps(obj: Any) -> bytes:
    # ensure_ascii=False: orjson emits raw UTF-8, so stored byte sizes
    # (index-size accounting) must not depend on which path is installed.
    # orjson serializes non-finite floats as null; stdlib would emit the
    # non-standard NaN/Infinity tokens orjson can't parse back — sanitize
    # (rare path) so both environments produce identical, valid bytes
    try:
        return json.dumps(obj, separators=(",", ":"), default=_default,
                          allow_nan=False, ensure_ascii=False).encode()
    except ValueError:
        return json.dumps(_sanitize(obj), separators=(",", ":"),
                          default=_default, allow_nan=False,
                          ensure_ascii=False).encode()


def loads(data: bytes | bytearray | memoryview | str) -> Any:
    if isinstance(data, (bytes, bytearray, memoryview)):
        data = bytes(data).decode()
    return json.loads(data)


try:
    import orjson as _orjson

    JSONDecodeError = _orjson.JSONDecodeError          # noqa: F811
    loads = _orjson.loads                              # noqa: F811

    def dumps(obj: Any) -> bytes:                      # noqa: F811
        # numpy option keeps the fast path exactly as permissive as the shim
        return _orjson.dumps(obj, option=_orjson.OPT_SERIALIZE_NUMPY)
except ImportError:
    pass
