"""Document partitioning + global top-k merge (paper §3's scaling path).

"This barrier to scalability ... can be straightforwardly solved by standard
document partitioning practices, where separate Lambda instances are assigned
to different partitions of the document collection."

Two realizations, same math:

* **Mesh-level** (`partitioned_topk`, `shard_topk_merge`): shards of the
  candidate/document axis live on different devices along a mesh axis; each
  device computes its local top-k; the k·P survivors are all-gathered and
  reduced to the global top-k. k ≪ N/P makes the collective tiny — this is
  why partition-then-merge is the right TPU mapping of the paper's design.

* **Fleet-level** (`ScatterGather`): one FaaS function per partition; the
  coordinator fans out a query to every partition's function and merges the
  per-partition hits. Latency = max over partitions (+merge), i.e. the
  straggler profile the runtime's hedging targets.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def local_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (scores, ids) along the last axis."""
    v, idx = jax.lax.top_k(scores, k)
    return v, jnp.take_along_axis(ids, idx, axis=-1)


def merge_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Merge candidate sets along the last axis into top-k (ties → lower id
    wins is NOT guaranteed; scores ordering only, like Lucene's by-score)."""
    return local_topk(scores, ids, k)


def shard_topk_merge(scores: jax.Array, ids: jax.Array, k: int, axis_name: str):
    """Inside shard_map: local top-k, all-gather survivors, global top-k.

    scores/ids: (..., n_local). Returns (..., k) replicated across axis_name.
    """
    lv, li = local_topk(scores, ids, k)
    gv = jax.lax.all_gather(lv, axis_name, axis=-1, tiled=True)   # (..., k*P)
    gi = jax.lax.all_gather(li, axis_name, axis=-1, tiled=True)
    return merge_topk(gv, gi, k)


def partitioned_topk(
    score_fn: Callable[..., jax.Array],
    mesh: jax.sharding.Mesh,
    axis_name: str,
    k: int,
    *,
    in_specs: Any,
    query_spec: Any = None,
) -> Callable[..., tuple[jax.Array, jax.Array]]:
    """Build a shard_map'd global-top-k scorer.

    ``score_fn(query, *state_shards) -> (..., n_local) scores`` runs per
    partition; doc ids are reconstructed as partition-local offsets shifted
    by the partition index so returned ids are global.
    """
    from jax.experimental.shard_map import shard_map

    def per_shard(query, *state):
        scores = score_fn(query, *state)
        n_local = scores.shape[-1]
        p = jax.lax.axis_index(axis_name)
        base = (p * n_local).astype(jnp.int32)
        ids = base + jnp.arange(n_local, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids, scores.shape)
        return shard_topk_merge(scores, ids, k, axis_name)

    qspec = query_spec if query_spec is not None else P()
    return shard_map(
        per_shard, mesh=mesh,
        in_specs=(qspec,) + tuple(in_specs),
        out_specs=(P(), P()),
        check_rep=False,
    )


# -- fleet-level scatter/gather ------------------------------------------------


@dataclasses.dataclass
class PartitionHit:
    doc_id: int
    score: float
    partition: int


class ScatterGather:
    """Fan a query out to one FaaS function per partition and merge hits."""

    def __init__(self, runtime, fn_names: Sequence[str]) -> None:
        self.runtime = runtime
        self.fn_names = list(fn_names)

    def search(self, payload: Any, k: int, *, t_arrival: float | None = None):
        all_hits: list[PartitionHit] = []
        lat = 0.0
        records = []
        for p, fn in enumerate(self.fn_names):
            # partitions execute concurrently on separate instances; latency
            # is the max, not the sum (scatter-gather semantics)
            result, rec = self.runtime.invoke(fn, payload, t_arrival=t_arrival)
            records.append(rec)
            lat = max(lat, rec.latency_s)
            for doc_id, score in zip(result["ids"], result["scores"]):
                all_hits.append(PartitionHit(int(doc_id), float(score), p))
        all_hits.sort(key=lambda h: -h.score)
        return all_hits[:k], lat, records
