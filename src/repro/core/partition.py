"""Document partitioning + global top-k merge (paper §3's scaling path).

"This barrier to scalability ... can be straightforwardly solved by standard
document partitioning practices, where separate Lambda instances are assigned
to different partitions of the document collection."

Two realizations, same math:

* **Mesh-level** (`partitioned_topk`, `shard_topk_merge`): shards of the
  candidate/document axis live on different devices along a mesh axis; each
  device computes its local top-k; the k·P survivors are all-gathered and
  reduced to the global top-k. k ≪ N/P makes the collective tiny — this is
  why partition-then-merge is the right TPU mapping of the paper's design.

* **Fleet-level** (`ScatterGather`): one FaaS function per partition; the
  coordinator fans out a query to every partition's function and merges the
  per-partition hits. Latency = max over partitions (+merge), i.e. the
  straggler profile the runtime's hedging targets. Partitions may be
  REPLICATED: a replica group serves one segment from R independent instance
  pools, and a `HedgePolicy` fires a backup leg on a replica whenever the
  primary's projected completion (queue + cold boot) exceeds a quantile of
  recent warm latencies — a cold or throttled pool then stops setting the
  fan-out max.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.runtime import RetriesExhausted, nearest_rank_percentiles

if TYPE_CHECKING:   # type-only: autoscale/gateway/index/search import upward
    from repro.core.autoscale import AutoscalePolicy
    from repro.core.gateway import WindowPolicy
    from repro.core.object_store import Backend
    from repro.core.runtime import RuntimeConfig
    from repro.index.builder import MergePolicy
    from repro.search.searcher import SearchConfig


def local_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (scores, ids) along the last axis."""
    v, idx = jax.lax.top_k(scores, k)
    return v, jnp.take_along_axis(ids, idx, axis=-1)


def merge_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Merge candidate sets along the last axis into top-k (ties → lower id
    wins is NOT guaranteed; scores ordering only, like Lucene's by-score)."""
    return local_topk(scores, ids, k)


def shard_topk_merge(scores: jax.Array, ids: jax.Array, k: int, axis_name: str):
    """Inside shard_map: local top-k, all-gather survivors, global top-k.

    scores/ids: (..., n_local). Returns (..., k) replicated across axis_name.
    """
    lv, li = local_topk(scores, ids, k)
    gv = jax.lax.all_gather(lv, axis_name, axis=-1, tiled=True)   # (..., k*P)
    gi = jax.lax.all_gather(li, axis_name, axis=-1, tiled=True)
    return merge_topk(gv, gi, k)


def partitioned_topk(
    score_fn: Callable[..., jax.Array],
    mesh: jax.sharding.Mesh,
    axis_name: str,
    k: int,
    *,
    in_specs: Any,
    query_spec: Any = None,
) -> Callable[..., tuple[jax.Array, jax.Array]]:
    """Build a shard_map'd global-top-k scorer.

    ``score_fn(query, *state_shards) -> (..., n_local) scores`` runs per
    partition; doc ids are reconstructed as partition-local offsets shifted
    by the partition index so returned ids are global.
    """
    from repro.parallel import compat

    def per_shard(query, *state):
        scores = score_fn(query, *state)
        n_local = scores.shape[-1]
        p = jax.lax.axis_index(axis_name)
        base = (p * n_local).astype(jnp.int32)
        ids = base + jnp.arange(n_local, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids, scores.shape)
        return shard_topk_merge(scores, ids, k, axis_name)

    qspec = query_spec if query_spec is not None else P()
    return compat.shard_map(
        per_shard, mesh,
        in_specs=(qspec,) + tuple(in_specs),
        out_specs=(P(), P()),
    )


# -- fleet-level scatter/gather ------------------------------------------------


# Gather-side work per scatter: collecting R×k candidate lists, the sort/merge
# in _merge_hits, and re-serialization at the coordinator. Constant and small,
# but charging it keeps end-to-end latency honest (B6/B7 were systematically
# optimistic without it).
MERGE_COST_S = 0.001


class GenerationMismatch(Exception):
    """A scatter's legs answered from DIFFERENT index generations.

    Merging such hits would be silently wrong — partition A scored under
    generation N's stats while partition B scored under N+1's (different
    idf/avgdl, different tombstones), so the merged ranking corresponds to
    no index that ever existed. The coordinator pins one generation per
    query precisely so this cannot happen; this guard turns any future
    regression (an unpinned payload, a handler ignoring the pin) into a
    loud failure instead of a subtly-torn result."""


@dataclasses.dataclass
class HedgePolicy:
    """When does a scatter leg deserve a backup on a replica?

    The decision is made AT DISPATCH from ``FaaSRuntime.probe``'s projection
    (queue wait + cold boot under the virtual clock) — not after waiting for
    the primary to run long, which would put the projected cold start itself
    on the critical path. A leg hedges when its projected overhead exceeds

    * ``after_s``, a fixed threshold, if set; otherwise
    * ``scale`` × the ``percentile`` quantile of the replica group's recent
      WARM latencies (``FaaSRuntime.latency_percentiles(group,
      warm_only=True)``), once at least ``min_history`` warm records exist.
      The default is 2× the MEDIAN, not a raw p95: with a handful of
      records one jit-compile or hydration spike IS the p95 and would quietly
      disarm hedging, while the median shrugs it off (the same robustness
      argument as tail-at-scale's "hedge after ~2× expected latency").

    With no fixed threshold and too little history the leg never hedges —
    the initial all-cold fan-out would otherwise double-bill every partition
    for backups that are just as cold as their primaries.
    """

    after_s: float | None = None
    percentile: float = 0.5
    scale: float = 2.0
    min_history: int = 4
    window: int = 256        # most-recent warm records considered

    @classmethod
    def from_cold_profile(cls, cold_overhead_s: float, warm_p50_s: float,
                          **kw) -> "HedgePolicy":
        """Derive ``scale`` from a measured cold profile.

        The 2× default encodes the FULL-hydration regime, where a cold leg
        costs ~10-20× a warm query and any projected overhead past 2× warm
        is worth a backup. Lazy hydration shrinks the cold penalty several
        fold (B13 measures it), which moves the break-even: hedging a leg
        whose worst case is only a few warm-medians buys little latency for
        a guaranteed double bill. The rule — backup when projected overhead
        exceeds about a TENTH of the cold penalty, expressed in warm
        medians, clamped to [1.25, 4]:

            scale = clamp(1 + cold_overhead_s / (10 × warm_p50_s), 1.25, 4.0)

        Full profile (cold ≈ 0.47 s, warm ≈ 0.025 s) → scale ≈ 2.9; the
        lazy profile (cold ≈ 0.2 s) → scale ≈ 1.8 — hedging gets MORE eager
        per warm-median because a backup is now cheap to be wrong about.
        Defaults stay the full-regime 2.0; fleets opting into lazy
        hydration re-derive explicitly."""
        if warm_p50_s <= 0 or math.isnan(warm_p50_s):
            return cls(**kw)
        scale = min(4.0, max(1.25, 1.0 + cold_overhead_s / (10.0 * warm_p50_s)))
        return cls(scale=scale, **kw)

    def threshold_s(self, runtime, group: Sequence[str]) -> float | None:
        """The projected-overhead threshold for this group, or None if the
        policy has no basis to hedge yet.

        One newest-first scan of the record log
        (``FaaSRuntime.recent_latencies``), stopping at ``window`` matches —
        "recent" by construction, per-query work bounded instead of growing
        with the run length, and the SAME windowing the fleet controller
        reads its warm quantiles through (``latency_percentiles(...,
        window=...)``): hedging and scaling must judge one latency regime,
        not hedge on recent behaviour while scaling on stale history."""
        if self.after_s is not None:
            return self.after_s
        warm = runtime.recent_latencies(group, warm_only=True,
                                        window=self.window)
        if len(warm) < self.min_history:
            return None
        q = nearest_rank_percentiles(warm, qs=(self.percentile,))
        return self.scale * q[self.percentile]


@dataclasses.dataclass
class PartitionHit:
    doc_id: int              # partition-LOCAL internal id
    score: float
    partition: int
    ext_id: str | None = None


def _merge_hits(per_part: list[dict], k: int) -> list[PartitionHit]:
    """Merge one query's per-partition result dicts into global top-k.

    Ties break by (partition, local id) — i.e. ascending global id under
    contiguous partitioning, matching the oracle's ordering."""
    hits: list[PartitionHit] = []
    for p, result in enumerate(per_part):
        ext = result.get("ext_ids") or [None] * len(result["ids"])
        for doc_id, score, e in zip(result["ids"], result["scores"], ext):
            hits.append(PartitionHit(int(doc_id), float(score), p, e))
    hits.sort(key=lambda h: (-h.score, h.partition, h.doc_id))
    return hits[:k]


# Reciprocal Rank Fusion constant (Cormack et al. '09's k=60): large enough
# that a doc ranked ~60 in one tier cannot outvote a doc ranked first in the
# other, small enough that agreement across tiers still dominates.
RRF_C = 60.0


def rrf_fuse(rankings: Sequence[Sequence[Any]], k: int, *,
             c: float = RRF_C) -> list[tuple[Any, float]]:
    """Reciprocal Rank Fusion over ranked key lists →
    top-k ``[(key, score)]`` with ``score = Σ_tiers 1 / (c + rank)``
    (rank is 1-based; a key absent from a tier contributes nothing).

    Rank-only fusion is what makes hybrid merge sound across tiers whose
    scores live on incomparable scales (BM25 impacts vs inner products).
    Deterministic by construction: ties break ascending on the key, and a
    key's per-tier contributions accumulate in tier order — the fleet
    coordinator and the oracle fusion call THIS function with tiers in the
    same (sparse, dense) order, so their fused floats are bit-identical,
    not merely close."""
    scores: dict[Any, float] = {}
    for ranking in rankings:
        for rank, key in enumerate(ranking, start=1):
            scores[key] = scores.get(key, 0.0) + 1.0 / (c + rank)
    fused = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
    return fused[:k]


# -- the fleet's typed assembly spec ------------------------------------------
#
# ``build_partitioned_search_app`` grew one keyword per PR until it was a
# 12-kwarg sprawl; these dataclasses are the redesigned surface. Groups
# mirror the fleet's actual seams — who serves (replication), how requests
# enter (gateway), what is served (index, including the dense-vector tier),
# and the runtime/search knobs. Validation happens ONCE at construction
# (``FleetSpec.__post_init__``), not scattered through assembly code.
# Imports are type-only (``TYPE_CHECKING``): core.autoscale imports this
# module, so the spec duck-types its policy fields at runtime.


@dataclasses.dataclass
class ReplicationSpec:
    """Who serves each partition: pool count, hedging, autoscaling."""

    replicas: int = 1
    # HedgePolicy, or a float shorthand for a fixed after_s threshold
    hedge: "HedgePolicy | float | None" = None
    # AutoscalePolicy, or True for defaults (resolved at assembly — the
    # policy class lives in core.autoscale, which imports this module)
    autoscale: "AutoscalePolicy | bool | None" = None
    # when a partition leg exhausts its retries: True merges the surviving
    # partitions' hits (a degraded but fast answer, flagged in the result);
    # False (default) surfaces the typed 503 — correctness over availability
    degraded_ok: bool = False

    def __post_init__(self) -> None:
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if isinstance(self.hedge, (int, float)) and not isinstance(
                self.hedge, bool):
            self.hedge = HedgePolicy(after_s=float(self.hedge))


@dataclasses.dataclass
class GatewaySpec:
    """How requests enter: admission window + scatter routing."""

    window: "WindowPolicy | None" = None
    routing: str | None = None     # None → "aware" iff autoscaling, "static" else

    def __post_init__(self) -> None:
        if self.routing not in (None, "static", "aware"):
            raise ValueError("routing must be None, 'static' or 'aware', "
                             f"got {self.routing!r}")


@dataclasses.dataclass
class VectorSpec:
    """The dense-vector tier: embedding shape + storage + embedder.

    ``embedder`` maps text → (dim,) f32; None resolves to the deterministic
    ``repro.data.corpus.hash_embedder(dim)`` at assembly. The same embedder
    derives doc vectors at indexing time and query vectors at the
    coordinator, so a text query needs no client-side vector."""

    dim: int = 16
    dtype: str = "float32"         # "float32" | "int8" (scalar-quantized)
    embedder: "Callable[[str], Any] | None" = None

    def __post_init__(self) -> None:
        if self.dim < 1:
            raise ValueError(f"vector dim must be >= 1, got {self.dim}")
        if self.dtype not in ("float32", "int8"):
            raise ValueError("vector dtype must be 'float32' or 'int8', "
                             f"got {self.dtype!r}")


@dataclasses.dataclass
class IndexSpec:
    """What is served: the document split, compaction policy, dense tier,
    and the structured (format-v2) tier.

    ``structured=True`` packs every segment in format v2 — per-posting
    stored occurrences, per-field lengths, and per-doc values for each
    ``facet_fields`` entry — which is what lets the fleet serve fielded
    scoring, positional phrases, facets, and snippets (``sq``/``sqs``
    bodies). Declaring any ``facet_fields`` implies ``structured``.
    Fleets that leave both defaulted publish byte-identical v1 segments
    and reject structured queries at admission (HTTP 400)."""

    partition_weights: "list[float] | None" = None
    merge_policy: "MergePolicy | None" = None
    vector: VectorSpec | None = None
    asset_prefix: str = "index"
    structured: bool = False
    facet_fields: "tuple[str, ...] | list[str]" = ()

    def __post_init__(self) -> None:
        self.facet_fields = tuple(self.facet_fields)
        self.structured = self.structured or bool(self.facet_fields)


@dataclasses.dataclass
class FleetSpec:
    """The whole fleet, validated at construction.

    ``build_partitioned_search_app(docs, FleetSpec(...))`` replaces the
    legacy kwarg sprawl (which still works through a deprecation shim)."""

    n_parts: int = 4
    replication: ReplicationSpec = dataclasses.field(
        default_factory=ReplicationSpec)
    gateway: GatewaySpec = dataclasses.field(default_factory=GatewaySpec)
    index: IndexSpec = dataclasses.field(default_factory=IndexSpec)
    runtime_config: "RuntimeConfig | None" = None
    search_config: "SearchConfig | None" = None
    backend: "Backend | None" = None

    def __post_init__(self) -> None:
        if self.n_parts < 1:
            raise ValueError(f"n_parts must be >= 1, got {self.n_parts}")
        w = self.index.partition_weights
        if w is not None:
            if len(w) != self.n_parts:
                raise ValueError(
                    f"partition_weights has {len(w)} entries for "
                    f"{self.n_parts} partitions")
            if any(x <= 0 for x in w):
                raise ValueError("partition_weights must be positive")


class ScatterGather:
    """Fan a query out to one FaaS function per partition and merge hits.

    Each entry of ``fn_names`` is either one function name (unreplicated
    partition) or a replica GROUP ``[primary, backup, ...]`` — every member
    serves the same published segment from its own instance pool. With a
    :class:`HedgePolicy`, a leg whose primary projects a completion past the
    policy threshold fires a backup on the group's best-projected replica at
    the same arrival instant; the first completion wins (bit-identical
    results either way) and both legs bill.

    ``routing`` picks the primary per dispatch:

    * ``"static"`` (default, PR 2 behaviour): the group's first member is
      always primary; replicas only ever see hedge traffic.
    * ``"aware"``: the primary ROTATES to the member with the best projected
      overhead (``FaaSRuntime.probe``) plus a penalty per recent
      ``kill_instance`` event in its pool — so after a pool loses an
      instance, the next queries route around it instead of hedging against
      it, and a backup leg never lands on the same struggling pool the
      policy is trying to escape. Ties break by group order, keeping
      dispatch deterministic (results are bit-identical either way: every
      member serves the same ``PackedIndex``).

    Groups are MUTABLE: a fleet controller may :meth:`add_replica` /
    :meth:`remove_replica` between dispatches to scale a partition's
    capacity against the cost ledger — the published segment never moves.
    """

    def __init__(self, runtime, fn_names: Sequence, *,
                 hedge: "HedgePolicy | None" = None,
                 merge_cost_s: float = MERGE_COST_S,
                 routing: str = "static",
                 kill_window_s: float = 30.0,
                 degraded_ok: bool = False) -> None:
        if routing not in ("static", "aware"):
            raise ValueError(f"routing must be 'static' or 'aware', got {routing!r}")
        self.runtime = runtime
        self.groups: list[list[str]] = [
            [g] if isinstance(g, str) else list(g) for g in fn_names]
        self.fn_names = [g[0] for g in self.groups]   # base primaries
        self.hedge = hedge
        self.merge_cost_s = merge_cost_s
        self.routing = routing
        self.kill_window_s = kill_window_s
        self.degraded_ok = degraded_ok
        self.last_versions: list[str] = []   # index versions of the last scatter
        self.last_degraded: list[int] = []   # partitions dropped (degraded_ok)

    # -- mutable replica groups (the autoscaler's levers) ---------------------

    def add_replica(self, partition: int, fn: str) -> None:
        """Grow ``partition``'s group with an already-registered function
        serving the same segment (scale-up: new pool, same asset)."""
        group = self.groups[partition]
        if fn in group:
            raise ValueError(f"{fn!r} already in partition {partition}'s group")
        group.append(fn)

    def remove_replica(self, partition: int, fn: str) -> None:
        """Shrink ``partition``'s group (scale-down). The last member can
        never be removed — a partition must keep one serving pool, or the
        fan-out would silently drop its documents from every result."""
        group = self.groups[partition]
        if fn not in group:
            raise ValueError(f"{fn!r} not in partition {partition}'s group")
        if len(group) == 1:
            raise ValueError(
                f"cannot remove {fn!r}: partition {partition}'s last replica")
        group.remove(fn)

    # -- dispatch -------------------------------------------------------------

    def _projected_overhead(self, fn: str, t0: float) -> float:
        return sum(self.runtime.probe(fn, t0))

    def _choose_primary(self, group: list[str], t0: float) -> str:
        """Pick this dispatch's primary. Aware routing scores each member by
        projected overhead plus one cold boot per recent kill in its pool
        (a kill the probe can't see yet — e.g. a pool with surviving idle
        instances — still deserves suspicion), lowest score wins."""
        if self.routing != "aware" or len(group) == 1:
            return group[0]
        provision = self.runtime.config.provision_s

        def score(fn: str) -> float:
            kills = self.runtime.recent_kills(
                fn, now=t0, window_s=self.kill_window_s)
            return self._projected_overhead(fn, t0) + provision * kills

        return min(enumerate(group), key=lambda p: (score(p[1]), p[0]))[1]

    def _invoke_leg(self, group: list[str], payload: Any, t0: float):
        """One partition leg: primary, plus a projection-triggered backup."""
        primary = self._choose_primary(group, t0)
        rest = [f for f in group if f != primary]
        if self.hedge is not None and rest:
            thresh = self.hedge.threshold_s(self.runtime, group)
            if thresh is not None:
                projected = self._projected_overhead(primary, t0)
                if projected > thresh:
                    backup = min(rest,
                                 key=lambda f: self._projected_overhead(f, t0))
                    # a replica projecting no better than the primary (both
                    # cold, or its queue just as deep) cannot win the race —
                    # firing it would double-bill for zero latency gain
                    if self._projected_overhead(backup, t0) < projected:
                        return self.runtime.invoke_hedged(
                            primary, backup, payload, t_arrival=t0)
        return self.runtime.invoke(primary, payload, t_arrival=t0)

    def scatter(self, payload: Any, *, t_arrival: float | None = None):
        """Invoke every partition leg at the SAME arrival instant.

        Partitions execute concurrently on separate instances, so every
        fan-out leg sees the fleet as it was at t_arrival — the runtime's
        shared virtual clock advances only after the whole scatter — and
        end-to-end latency is the max over partitions plus the gather/merge
        term ``merge_cost_s`` (charged identically on the single-query and
        batched paths). Returns (per-partition results, latency_s, records).

        A leg whose retries run out (:class:`~repro.core.runtime.
        RetriesExhausted`) either aborts the whole scatter (``degraded_ok=
        False`` — the gateway maps it to a typed 503) or, with
        ``degraded_ok=True``, is replaced by an EMPTY result so the
        surviving partitions still merge: a degraded answer, recorded in
        ``last_degraded``, never a silently-partial one masquerading as
        complete. If every leg dies there is nothing to degrade TO, and the
        first leg's error propagates."""
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        results, records = [], []
        self.last_degraded = []
        first_err: RetriesExhausted | None = None
        for p, group in enumerate(self.groups):
            try:
                result, rec = self._invoke_leg(group, payload, t0)
            except RetriesExhausted as e:
                if not self.degraded_ok:
                    raise
                first_err = first_err or e
                self.last_degraded.append(p)
                results.append(self._degraded_result(payload))
                continue
            results.append(result)
            records.append(rec)
        if first_err is not None and not records:
            raise first_err             # nothing survived to answer from
        self._check_generations(results)
        lat = max((r.latency_s for r in records), default=0.0)
        return results, lat + self.merge_cost_s, records

    @staticmethod
    def _empty_hits() -> dict:
        return {"ids": [], "scores": [], "ext_ids": [],
                "dense": {"ids": [], "scores": [], "ext_ids": []}}

    def _degraded_result(self, payload: Any) -> dict:
        """A well-formed empty stand-in for a dead leg: contributes no hits
        to the merge and no version to the generation check (the dead leg
        answered from NO generation)."""
        if isinstance(payload, dict) and "queries" in payload:
            return {"results": [self._empty_hits()
                                for _ in payload["queries"]]}
        return self._empty_hits()

    def _check_generations(self, results: list) -> None:
        """Every leg that reports an index version must report the SAME one
        — hedged replicas and freshly-scaled pools included, and BOTH tiers
        of a hybrid leg (``vec_version`` is the dense tier's): a sparse
        tier at generation N fused with a dense tier at N+1 would rank
        under two different tombstone sets in one result. See
        :class:`GenerationMismatch`."""
        versions = set()
        for r in results:
            if not isinstance(r, dict):
                continue
            if "version" in r:
                versions.add(r["version"])
            if "vec_version" in r:
                versions.add(r["vec_version"])
        self.last_versions = sorted(versions)
        if len(versions) > 1:
            raise GenerationMismatch(
                f"scatter legs answered from {sorted(versions)} — a query "
                "may never merge hits across index generations (nor across "
                "tiers of different generations)")

    def search(self, payload: Any, k: int, *, t_arrival: float | None = None):
        """Single-query scatter-gather: merged top-k hits."""
        results, lat, records = self.scatter(payload, t_arrival=t_arrival)
        return _merge_hits(results, k), lat, records

    def search_batch(self, payload: Any, k: int, *,
                     t_arrival: float | None = None):
        """Micro-batched scatter-gather: ``payload["queries"]`` is a list;
        every partition evaluates the whole batch in one invocation and the
        per-query candidate sets merge independently. Returns
        (list of per-query top-k hit lists, latency_s, records)."""
        results, lat, records = self.scatter(payload, t_arrival=t_arrival)
        n_q = len(payload["queries"])
        merged = [
            _merge_hits([r["results"][qi] for r in results], k)
            for qi in range(n_q)
        ]
        return merged, lat, records
