"""Document partitioning + global top-k merge (paper §3's scaling path).

"This barrier to scalability ... can be straightforwardly solved by standard
document partitioning practices, where separate Lambda instances are assigned
to different partitions of the document collection."

Two realizations, same math:

* **Mesh-level** (`partitioned_topk`, `shard_topk_merge`): shards of the
  candidate/document axis live on different devices along a mesh axis; each
  device computes its local top-k; the k·P survivors are all-gathered and
  reduced to the global top-k. k ≪ N/P makes the collective tiny — this is
  why partition-then-merge is the right TPU mapping of the paper's design.

* **Fleet-level** (`ScatterGather`): one FaaS function per partition; the
  coordinator fans out a query to every partition's function and merges the
  per-partition hits. Latency = max over partitions (+merge), i.e. the
  straggler profile the runtime's hedging targets.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def local_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (scores, ids) along the last axis."""
    v, idx = jax.lax.top_k(scores, k)
    return v, jnp.take_along_axis(ids, idx, axis=-1)


def merge_topk(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Merge candidate sets along the last axis into top-k (ties → lower id
    wins is NOT guaranteed; scores ordering only, like Lucene's by-score)."""
    return local_topk(scores, ids, k)


def shard_topk_merge(scores: jax.Array, ids: jax.Array, k: int, axis_name: str):
    """Inside shard_map: local top-k, all-gather survivors, global top-k.

    scores/ids: (..., n_local). Returns (..., k) replicated across axis_name.
    """
    lv, li = local_topk(scores, ids, k)
    gv = jax.lax.all_gather(lv, axis_name, axis=-1, tiled=True)   # (..., k*P)
    gi = jax.lax.all_gather(li, axis_name, axis=-1, tiled=True)
    return merge_topk(gv, gi, k)


def partitioned_topk(
    score_fn: Callable[..., jax.Array],
    mesh: jax.sharding.Mesh,
    axis_name: str,
    k: int,
    *,
    in_specs: Any,
    query_spec: Any = None,
) -> Callable[..., tuple[jax.Array, jax.Array]]:
    """Build a shard_map'd global-top-k scorer.

    ``score_fn(query, *state_shards) -> (..., n_local) scores`` runs per
    partition; doc ids are reconstructed as partition-local offsets shifted
    by the partition index so returned ids are global.
    """
    from repro.parallel import compat

    def per_shard(query, *state):
        scores = score_fn(query, *state)
        n_local = scores.shape[-1]
        p = jax.lax.axis_index(axis_name)
        base = (p * n_local).astype(jnp.int32)
        ids = base + jnp.arange(n_local, dtype=jnp.int32)
        ids = jnp.broadcast_to(ids, scores.shape)
        return shard_topk_merge(scores, ids, k, axis_name)

    qspec = query_spec if query_spec is not None else P()
    return compat.shard_map(
        per_shard, mesh,
        in_specs=(qspec,) + tuple(in_specs),
        out_specs=(P(), P()),
    )


# -- fleet-level scatter/gather ------------------------------------------------


@dataclasses.dataclass
class PartitionHit:
    doc_id: int              # partition-LOCAL internal id
    score: float
    partition: int
    ext_id: str | None = None


def _merge_hits(per_part: list[dict], k: int) -> list[PartitionHit]:
    """Merge one query's per-partition result dicts into global top-k.

    Ties break by (partition, local id) — i.e. ascending global id under
    contiguous partitioning, matching the oracle's ordering."""
    hits: list[PartitionHit] = []
    for p, result in enumerate(per_part):
        ext = result.get("ext_ids") or [None] * len(result["ids"])
        for doc_id, score, e in zip(result["ids"], result["scores"], ext):
            hits.append(PartitionHit(int(doc_id), float(score), p, e))
    hits.sort(key=lambda h: (-h.score, h.partition, h.doc_id))
    return hits[:k]


class ScatterGather:
    """Fan a query out to one FaaS function per partition and merge hits."""

    def __init__(self, runtime, fn_names: Sequence[str]) -> None:
        self.runtime = runtime
        self.fn_names = list(fn_names)

    def scatter(self, payload: Any, *, t_arrival: float | None = None):
        """Invoke every partition fn at the SAME arrival instant.

        Partitions execute concurrently on separate instances, so every
        fan-out leg sees the fleet as it was at t_arrival — the runtime's
        shared virtual clock advances only after the whole scatter — and
        end-to-end latency is the max over partitions, not the sum.
        Returns (per-partition results, latency_s, records)."""
        t0 = self.runtime.clock if t_arrival is None else t_arrival
        results, records = [], []
        for fn in self.fn_names:
            result, rec = self.runtime.invoke(fn, payload, t_arrival=t0)
            results.append(result)
            records.append(rec)
        lat = max((r.latency_s for r in records), default=0.0)
        return results, lat, records

    def search(self, payload: Any, k: int, *, t_arrival: float | None = None):
        """Single-query scatter-gather: merged top-k hits."""
        results, lat, records = self.scatter(payload, t_arrival=t_arrival)
        return _merge_hits(results, k), lat, records

    def search_batch(self, payload: Any, k: int, *,
                     t_arrival: float | None = None):
        """Micro-batched scatter-gather: ``payload["queries"]`` is a list;
        every partition evaluates the whole batch in one invocation and the
        per-query candidate sets merge independently. Returns
        (list of per-query top-k hit lists, latency_s, records)."""
        results, lat, records = self.scatter(payload, t_arrival=t_arrival)
        n_q = len(payload["queries"])
        merged = [
            _merge_hits([r["results"][qi] for r in results], k)
            for qi in range(n_q)
        ]
        return merged, lat, records
