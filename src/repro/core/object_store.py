"""S3-analogue object store.

The paper stores Lucene index structures in Amazon S3 and reads them from
Lambda through a custom ``Directory``. This module provides the store side of
that seam: immutable, versioned blobs addressed by key, with etags,
byte-range reads, listing, and multipart upload. Two backends:

* ``MemoryBackend`` — dict-of-bytes; used by tests and the FaaS simulator.
* ``FilesystemBackend`` — one file per object under a root dir; used by the
  examples and checkpointing (survives process restarts, which is what makes
  the "stateless compute / durable state" split real).

Latency/throughput accounting is injected via ``NetworkModel`` so the FaaS
simulator can charge realistic cold-start hydration times (S3 GET latency +
bandwidth) without any real network.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import threading
import time


class ObjectStoreError(Exception):
    pass


class NoSuchKey(ObjectStoreError):
    pass


class PreconditionFailed(ObjectStoreError):
    """Conditional put failed (etag mismatch) — used for atomic publishes."""


def _etag(data: bytes) -> str:
    return hashlib.md5(data).hexdigest()


@dataclasses.dataclass(frozen=True)
class ObjectMeta:
    key: str
    size: int
    etag: str
    mtime: float


@dataclasses.dataclass
class NetworkModel:
    """Models datacenter-network reads from the store (paper §2: 'bytes are

    now streamed across the datacenter network from S3'). Pure accounting —
    never sleeps; simulated seconds are returned/accumulated so benchmarks
    can report S3-like hydration costs deterministically.
    """

    first_byte_s: float = 0.015      # S3 GET time-to-first-byte (~15 ms)
    bandwidth_Bps: float = 90e6      # ~90 MB/s per stream (S3 single-stream)
    metadata_s: float = 0.005        # HEAD / LIST round-trip

    def read_cost_s(self, nbytes: int) -> float:
        return self.first_byte_s + nbytes / self.bandwidth_Bps

    def metadata_cost_s(self) -> float:
        return self.metadata_s


class Backend:
    """Minimal blob backend interface.

    ``get`` takes the byte range: the store's ranged reads must move only
    the requested bytes through the backend (a seek on the filesystem, a
    slice in memory), so the real bytes moved always match the bytes
    ``NetworkModel.read_cost_s`` bills for. ``length=None`` reads to EOF.
    """

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str, start: int = 0, length: int | None = None) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self) -> list[str]:
        raise NotImplementedError

    def __contains__(self, key: str) -> bool:
        try:
            self.get(key)
            return True
        except NoSuchKey:
            return False


class MemoryBackend(Backend):
    def __init__(self) -> None:
        self._blobs: dict[str, bytes] = {}
        self._lock = threading.Lock()

    def put(self, key: str, data: bytes) -> None:
        with self._lock:
            self._blobs[key] = bytes(data)

    def get(self, key: str, start: int = 0, length: int | None = None) -> bytes:
        with self._lock:
            try:
                data = self._blobs[key]
            except KeyError:
                raise NoSuchKey(key) from None
        if start == 0 and length is None:
            return data
        end = len(data) if length is None else start + length
        return data[start:end]

    def delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._blobs)


class FilesystemBackend(Backend):
    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, key: str) -> str:
        if ".." in key.split("/"):
            raise ObjectStoreError(f"illegal key {key!r}")
        return os.path.join(self.root, key)

    def put(self, key: str, data: bytes) -> None:
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic publish, like S3 PUT visibility

    def get(self, key: str, start: int = 0, length: int | None = None) -> bytes:
        # seek-based ranged read: a byte-range GET moves only the requested
        # bytes off disk, matching what the network model bills for
        try:
            with open(self._path(key), "rb") as f:
                if start:
                    f.seek(start)
                return f.read() if length is None else f.read(length)
        except FileNotFoundError:
            raise NoSuchKey(key) from None

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> list[str]:
        out = []
        for dirpath, _, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".tmp"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn), self.root)
                out.append(rel.replace(os.sep, "/"))
        return sorted(out)


class ObjectStore:
    """Versioned, etag'd blob store with range reads and simulated latency."""

    def __init__(self, backend: Backend | None = None,
                 network: NetworkModel | None = None) -> None:
        self.backend = backend if backend is not None else MemoryBackend()
        self.network = network if network is not None else NetworkModel()
        self._meta: dict[str, ObjectMeta] = {}
        self._lock = threading.Lock()
        self.stats = StoreStats()
        # rebuild metadata for pre-existing objects (fs backend reuse)
        for key in self.backend.keys():
            data = self.backend.get(key)
            self._meta[key] = ObjectMeta(key, len(data), _etag(data), time.time())

    # -- write path ---------------------------------------------------------

    def put(self, key: str, data: bytes, *, if_etag: str | None = None) -> ObjectMeta:
        with self._lock:
            if if_etag is not None:
                cur = self._meta.get(key)
                cur_etag = cur.etag if cur else ""
                if cur_etag != if_etag:
                    raise PreconditionFailed(f"{key}: etag {cur_etag!r} != {if_etag!r}")
            self.backend.put(key, data)
            meta = ObjectMeta(key, len(data), _etag(data), time.time())
            self._meta[key] = meta
            self.stats.puts += 1
            self.stats.bytes_in += len(data)
            return meta

    def multipart(self, key: str) -> "MultipartUpload":
        return MultipartUpload(self, key)

    def delete(self, key: str) -> None:
        with self._lock:
            self.backend.delete(key)
            self._meta.pop(key, None)

    # -- read path ----------------------------------------------------------

    def head(self, key: str) -> ObjectMeta:
        with self._lock:
            meta = self._meta.get(key)
        if meta is None:
            raise NoSuchKey(key)
        self.stats.sim_seconds += self.network.metadata_cost_s()
        return meta

    def get(self, key: str, *, start: int = 0, length: int | None = None) -> bytes:
        """Byte-range GET (the Directory seam relies on ranged reads).

        The range is pushed into the backend (filesystem seek / memory
        slice), never served by fetching the whole object and slicing: the
        real bytes moved are exactly the bytes the network model charges
        ``read_cost_s`` for. Bounds come from the store's own metadata, so
        an out-of-range start still fails loudly without touching data."""
        with self._lock:
            meta = self._meta.get(key)
        if meta is None:
            raise NoSuchKey(key)
        size = meta.size
        if start < 0 or start > size:
            raise ObjectStoreError(f"{key}: bad range start={start} size={size}")
        end = size if length is None else min(start + length, size)
        chunk = self.backend.get(key, start, max(0, end - start))
        self.stats.gets += 1
        self.stats.bytes_out += len(chunk)
        self.stats.sim_seconds += self.network.read_cost_s(len(chunk))
        return chunk

    def list(self, prefix: str = "") -> list[ObjectMeta]:
        self.stats.sim_seconds += self.network.metadata_cost_s()
        with self._lock:
            return [m for k, m in sorted(self._meta.items()) if k.startswith(prefix)]

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._meta


@dataclasses.dataclass
class StoreStats:
    gets: int = 0
    puts: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    sim_seconds: float = 0.0   # accumulated simulated network time


class MultipartUpload:
    """S3-style multipart upload: parts buffered, object visible on complete."""

    def __init__(self, store: ObjectStore, key: str) -> None:
        self.store = store
        self.key = key
        self._buf = io.BytesIO()
        self._done = False

    def write(self, part: bytes) -> None:
        if self._done:
            raise ObjectStoreError("upload already completed")
        self._buf.write(part)

    def complete(self) -> ObjectMeta:
        self._done = True
        return self.store.put(self.key, self._buf.getvalue())

    def abort(self) -> None:
        self._done = True
        self._buf = io.BytesIO()
