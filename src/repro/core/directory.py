"""The Lucene ``Directory`` seam, adapted.

Lucene reads indexes through ``Directory``: open a named file, read bytes,
seek. The paper's whole trick is swapping the implementation (``S3Directory``)
under an *unchanged* query-evaluation stack. We preserve that seam:

* ``Directory`` — abstract: ``open_input(name) -> IndexInput``, ``list()``.
* ``IndexInput`` — positioned byte reader (read/seek/slice), Lucene-style.
* ``StoreDirectory`` — reads from an :class:`ObjectStore` prefix, with a
  block cache (this is the paper's §2 caching mechanism: reads populate an
  in-memory cache so warm instances never touch the store again).
* ``RamDirectory`` — fully in-memory (tests, and the "everything hydrated"
  steady state).

On the TPU side the searcher hydrates *whole segments* through this seam into
packed arrays (see DESIGN.md §2 — eager, segment-granular hydration replaces
Lucene's lazy byte faulting, which has no HBM analogue).
"""

from __future__ import annotations

import struct
import threading

from repro.core.object_store import NoSuchKey, ObjectStore


class DirectoryError(Exception):
    pass


class IndexInput:
    """Positioned reader over one named index file."""

    def __init__(self, name: str, read_range, size: int):
        self._name = name
        self._read_range = read_range     # (start, length) -> bytes
        self._size = size
        self._pos = 0

    # -- Lucene-ish surface ---------------------------------------------------

    def length(self) -> int:
        return self._size

    def tell(self) -> int:
        return self._pos

    def seek(self, pos: int) -> None:
        if not (0 <= pos <= self._size):
            raise DirectoryError(f"{self._name}: seek({pos}) out of [0,{self._size}]")
        self._pos = pos

    def read_bytes(self, n: int) -> bytes:
        if self._pos + n > self._size:
            raise DirectoryError(f"{self._name}: read past EOF")
        out = self._read_range(self._pos, n)
        self._pos += n
        return out

    def read_all(self) -> bytes:
        self.seek(0)
        return self.read_bytes(self._size)

    def read_u32(self) -> int:
        return struct.unpack("<I", self.read_bytes(4))[0]

    def read_u64(self) -> int:
        return struct.unpack("<Q", self.read_bytes(8))[0]

    def read_f32(self) -> float:
        return struct.unpack("<f", self.read_bytes(4))[0]

    def slice(self, offset: int, length: int) -> "IndexInput":
        if offset + length > self._size:
            raise DirectoryError(f"{self._name}: slice past EOF")
        base = self._read_range
        return IndexInput(
            f"{self._name}[{offset}:{offset+length}]",
            lambda s, n: base(offset + s, n),
            length,
        )


class Directory:
    def open_input(self, name: str) -> IndexInput:
        raise NotImplementedError

    def list(self) -> list[str]:
        raise NotImplementedError

    def file_length(self, name: str) -> int:
        raise NotImplementedError

    def __contains__(self, name: str) -> bool:
        return name in self.list()


class RamDirectory(Directory):
    def __init__(self, files: dict[str, bytes] | None = None) -> None:
        self.files: dict[str, bytes] = dict(files or {})

    def write(self, name: str, data: bytes) -> None:
        self.files[name] = bytes(data)

    def open_input(self, name: str) -> IndexInput:
        try:
            data = self.files[name]
        except KeyError:
            raise DirectoryError(f"no such file {name!r}") from None
        return IndexInput(name, lambda s, n: data[s : s + n], len(data))

    def list(self) -> list[str]:
        return sorted(self.files)

    def file_length(self, name: str) -> int:
        return len(self.files[name])


class StoreDirectory(Directory):
    """Directory over an ObjectStore prefix, with a read-through block cache.

    Cache granularity is ``block_size`` bytes, mirroring S3Directory's
    buffered reads. ``cache_stats`` exposes hit/miss/bytes so the FaaS
    simulator can distinguish cold (cache-populating) from warm invocations.
    """

    def __init__(self, store: ObjectStore, prefix: str, *,
                 block_size: int = 1 << 20) -> None:
        if prefix and not prefix.endswith("/"):
            prefix += "/"
        self.store = store
        self.prefix = prefix
        self.block_size = block_size
        self._blocks: dict[tuple[str, int], bytes] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.bytes_fetched = 0

    # -- cache ---------------------------------------------------------------

    def _read_range(self, key: str, size: int, start: int, n: int) -> bytes:
        """Read [start, start+n) of object `key`, block-cached."""
        bs = self.block_size
        out = bytearray()
        blk = start // bs
        while blk * bs < start + n:
            ck = (key, blk)
            with self._lock:
                block = self._blocks.get(ck)
            if block is None:
                self.misses += 1
                lo = blk * bs
                block = self.store.get(key, start=lo, length=min(bs, size - lo))
                self.bytes_fetched += len(block)
                with self._lock:
                    self._blocks[ck] = block
            else:
                self.hits += 1
            lo = blk * bs
            s = max(start, lo) - lo
            e = min(start + n, lo + len(block)) - lo
            out += block[s:e]
            blk += 1
        return bytes(out)

    def drop_cache(self) -> None:
        with self._lock:
            self._blocks.clear()

    def cached_bytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._blocks.values())

    # -- Directory surface -----------------------------------------------------

    def open_input(self, name: str) -> IndexInput:
        key = self.prefix + name
        try:
            meta = self.store.head(key)
        except NoSuchKey:
            raise DirectoryError(f"no such file {name!r} under {self.prefix!r}") from None
        return IndexInput(
            name, lambda s, n: self._read_range(key, meta.size, s, n), meta.size
        )

    def list(self) -> list[str]:
        plen = len(self.prefix)
        return [m.key[plen:] for m in self.store.list(self.prefix)]

    def file_length(self, name: str) -> int:
        return self.store.head(self.prefix + name).size


def copy_directory(src: Directory, dst_store: ObjectStore, prefix: str) -> None:
    """Upload every file in `src` under `prefix` (multipart for big files)."""
    if prefix and not prefix.endswith("/"):
        prefix += "/"
    for name in src.list():
        data = src.open_input(name).read_all()
        up = dst_store.multipart(prefix + name)
        for off in range(0, len(data), 8 << 20):
            up.write(data[off : off + (8 << 20)])
        up.complete()
