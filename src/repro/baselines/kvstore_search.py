"""Crane & Lin (ICTIR 2017) baseline: postings lists in a KV store.

The design the paper improves on: "postings lists are stored in the DynamoDB
data store and query execution is handled by Lambda. ... End-to-end query
latency was around three seconds."

Every query term costs a DynamoDB round-trip to fetch its (full) postings
list, plus value deserialization at DynamoDB throughput; scoring happens in
plain Python (their custom query evaluator — no Lucene). No cache: DynamoDB
*is* the index store, so every invocation pays the fetches again. That
per-query store traffic is exactly why Anlessini's hydrate-once design wins
an order of magnitude.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter

from repro.core.kvstore import KVModel, KVStore
from repro.index.tokenizer import tokenize


@dataclasses.dataclass
class KVPostingsConfig:
    k1: float = 0.9
    b: float = 0.4
    # DynamoDB read throughput for large items (postings are big values):
    # ~1MB/s effective for sequential 400KB-item pages circa 2017.
    value_Bps: float = 4e6
    item_page_bytes: int = 400 << 10   # DynamoDB max item size 400KB → paging
    python_score_s_per_posting: float = 2.0e-7


class KVPostingsIndex:
    """Builds the baseline layout: one KV item (or page chain) per term."""

    def __init__(self, kv: KVStore | None = None,
                 config: KVPostingsConfig | None = None) -> None:
        self.kv = kv if kv is not None else KVStore(KVModel())
        self.config = config or KVPostingsConfig()
        self.n_docs = 0
        self.avgdl = 0.0

    def build(self, docs: list[tuple[str, str]]) -> None:
        postings: dict[str, dict[int, int]] = {}
        doc_len = []
        for i, (_, text) in enumerate(docs):
            toks = tokenize(text)
            doc_len.append(len(toks))
            for t, tf in Counter(toks).items():
                postings.setdefault(t, {})[i] = min(tf, 255)
        self.n_docs = len(docs)
        self.avgdl = sum(doc_len) / max(1, len(doc_len))
        self.kv.put("__stats__", {"n_docs": self.n_docs, "avgdl": self.avgdl,
                                  "doc_len": doc_len})
        for term, plist in postings.items():
            self.kv.put(f"p/{term}", {
                "df": len(plist),
                "docs": list(plist.keys()),
                "tfs": list(plist.values()),
            })

    # -- query path (the ~3s design) ------------------------------------------

    def search(self, query: str, k: int = 10):
        """Returns (hits, simulated_latency_s)."""
        cfg = self.config
        sim_s = 0.0
        stats = self.kv.get("__stats__")
        sim_s += self.kv.model.get_s
        n_docs, avgdl, doc_len = stats["n_docs"], stats["avgdl"], stats["doc_len"]

        scores: dict[int, float] = {}
        n_postings = 0
        for term, qtf in Counter(tokenize(query)).items():
            key = f"p/{term}"
            if key not in self.kv:
                continue
            item = self.kv.get(key)
            df = item["df"]
            # value transfer: postings bytes at DynamoDB throughput, paged
            nbytes = df * 8
            pages = max(1, -(-nbytes // cfg.item_page_bytes))
            sim_s += pages * self.kv.model.get_s + nbytes / cfg.value_Bps
            idf = math.log(1.0 + (n_docs - df + 0.5) / (df + 0.5))
            for doc, tf in zip(item["docs"], item["tfs"]):
                dl = doc_len[doc]
                denom = tf + cfg.k1 * (1 - cfg.b + cfg.b * dl / avgdl)
                scores[doc] = scores.get(doc, 0.0) + qtf * idf * tf / denom
            n_postings += df
        sim_s += n_postings * cfg.python_score_s_per_posting
        ranked = sorted(scores.items(), key=lambda kv_: (-kv_[1], kv_[0]))[:k]
        return ranked, sim_s
