"""AdamW + global-norm clipping + warmup-cosine schedule (no optax on box).

Moments are kept in fp32 regardless of param dtype (bf16 params + fp32
moments; no separate fp32 master copy — the memory budget note is in
DESIGN.md §5). The update is written as a pure pytree map so it inherits
whatever sharding the parameters carry (FSDP shards moments for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), grads), g


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: OptConfig) -> tuple[Any, dict]:
    """Returns (new_params, new_opt_state)."""
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
