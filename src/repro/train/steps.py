"""Generic train/serve step builders shared by every architecture family.

``make_train_step(loss_fn, opt_cfg)`` returns a pure
``step(state, batch) -> (state, metrics)`` where
``state = {"params": ..., "opt": adamw_state}``. The step is jit-compiled by
the caller (launch/train.py, launch/dryrun.py) with explicit in/out
shardings and state donation — the builders stay mesh-agnostic.

Optional gradient compression: cast grads to bf16 *before* the (GSPMD-
inserted) cross-replica reduction by computing the loss in a bf16-grad
context — here realized as a post-backward cast with stochastic-rounding-
free bf16 (documented accuracy note), halving all-reduce bytes on the slow
cross-pod axis.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optim import (OptConfig, adamw_init, adamw_update,
                               clip_by_global_norm)


def init_train_state(params: Any) -> dict:
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(loss_fn: Callable[[Any, Any], tuple[jax.Array, dict]],
                    opt_cfg: OptConfig, *,
                    compress_grads: bool = False) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics)."""

    def step(state: dict, batch: Any) -> tuple[dict, dict]:
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (loss, metrics), grads = grad_fn(state["params"], batch)
        if compress_grads:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
        if opt_cfg.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        else:
            from repro.train.optim import global_norm
            gnorm = global_norm(grads)
        params, opt = adamw_update(grads, state["opt"], state["params"], opt_cfg)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["step"] = opt["count"]
        return {"params": params, "opt": opt}, metrics

    return step


def make_eval_step(loss_fn: Callable) -> Callable:
    def step(params: Any, batch: Any) -> dict:
        _, metrics = loss_fn(params, batch)
        return metrics
    return step
