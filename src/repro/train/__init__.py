"""Training substrate: AdamW + schedules (optim), generic step builders
(steps). No optax dependency — the optimizer is implemented here."""

from repro.train.optim import OptConfig, adamw_init, adamw_update, schedule
from repro.train.steps import init_train_state, make_eval_step, make_train_step

__all__ = ["OptConfig", "adamw_init", "adamw_update", "schedule",
           "init_train_state", "make_eval_step", "make_train_step"]
